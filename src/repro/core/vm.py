"""Virtual-memory arena implementing the paper's §3.2 for real on Linux.

Superblocks live inside one large anonymous private mapping.  Releasing a
*persistent* superblock must keep its address range readable while giving the
physical frames back to the OS.  Three strategies (paper §3.1–§3.2):

- ``KEEP``          — allocator-level recycling only: frames are kept; memory
                      is reusable by the whole process but never returned to
                      the OS (the paper's first, portable solution).
- ``MADVISE``       — ``madvise(MADV_DONTNEED)``: pages revert to the shared
                      zero copy-on-write frame.  Reads stay valid (return 0),
                      frames are freed immediately (Linux semantics).
- ``SHARED_REMAP``  — ``mmap(MAP_FIXED|MAP_SHARED)`` the dead range onto one
                      pre-reserved shared region backed by a single set of
                      frames (memfd).  Arbitrarily many dead superblocks cost
                      one superblock of physical memory.  Reuse remaps the
                      range ``MAP_FIXED|MAP_PRIVATE|MAP_ANONYMOUS``.

Non-persistent superblocks are "released to the OS"; in this single-mapping
arena that is modelled as ``MADV_DONTNEED`` (frames dropped) plus returning
the index to the free stack — physically equivalent to unmap+remap of the
same range, without fragmenting the Python mmap object.

``resident_pages`` measures actual physical residency via ``mincore(2)`` so
tests and benchmarks can *prove* frames were released (paper Fig. 3).
"""

from __future__ import annotations

import ctypes
import ctypes.util
import enum
import mmap
import os
import threading

_libc = ctypes.CDLL(ctypes.util.find_library("c") or "libc.so.6", use_errno=True)

_libc.mmap.restype = ctypes.c_void_p
_libc.mmap.argtypes = [
    ctypes.c_void_p,
    ctypes.c_size_t,
    ctypes.c_int,
    ctypes.c_int,
    ctypes.c_int,
    ctypes.c_long,
]
_libc.mincore.restype = ctypes.c_int
_libc.mincore.argtypes = [ctypes.c_void_p, ctypes.c_size_t, ctypes.c_char_p]

PROT_READ = 0x1
PROT_WRITE = 0x2
MAP_SHARED = 0x01
MAP_PRIVATE = 0x02
MAP_FIXED = 0x10
MAP_ANONYMOUS = 0x20

PAGE_SIZE = os.sysconf("SC_PAGESIZE")


class ReleaseStrategy(enum.Enum):
    """How released superblocks relate to the OS (paper §3.1–§3.2).

    Shared vocabulary between the host arena (this module) and the device
    page pool (``core.pagepool`` / the serving engine): ``KEEP`` recycles
    within the process but never releases; ``MADVISE`` / ``SHARED_REMAP``
    release physical frames while the virtual range stays readable.
    """

    KEEP = "keep"
    MADVISE = "madvise"
    SHARED_REMAP = "shared_remap"


def superblock_floor(distinct_live_pages: int, pages_per_superblock: int,
                     min_mapped: int = 1) -> int:
    """Mapped-superblock floor a release must respect, given demand.

    ``distinct_live_pages`` must count every page ONCE no matter how many
    holders reference it: with the refcount layer a prompt-prefix page can
    back several requests plus the prefix cache simultaneously, and summing
    per-request footprints would overstate demand — pinning superblocks
    mapped that are actually releasable.  The caller (the engine's
    quiescence shrink) computes the distinct count from its host mirrors;
    this helper just turns pages into a superblock floor:
    ``max(min_mapped, ceil(pages / pages_per_superblock))``.
    """
    if pages_per_superblock <= 0:
        raise ValueError("pages_per_superblock must be positive")
    need = -(-max(0, distinct_live_pages) // pages_per_superblock)
    return max(min_mapped, need)


class Arena:
    """A contiguous region carved into equal-size superblocks.

    "Pointers" handed to the rest of the system are integer byte offsets into
    the arena (offset 0 is reserved as NULL).  ``view`` exposes the raw bytes;
    reads through it remain valid after any release strategy — that is the
    paper's core guarantee.
    """

    def __init__(
        self,
        num_superblocks: int = 64,
        superblock_size: int = 256 * 1024,
        strategy: ReleaseStrategy = ReleaseStrategy.MADVISE,
    ):
        if superblock_size % PAGE_SIZE:
            raise ValueError("superblock size must be page-aligned")
        self.sb_size = superblock_size
        self.num_sb = num_superblocks
        self.total = num_superblocks * superblock_size
        self.strategy = strategy
        self._mm = mmap.mmap(-1, self.total)  # MAP_PRIVATE|MAP_ANONYMOUS
        self.view = memoryview(self._mm)
        self._base = ctypes.addressof(ctypes.c_char.from_buffer(self._mm))
        self._lock = threading.Lock()
        # Free superblock indices; index 0's first 16 bytes are burned so that
        # offset 0 can serve as NULL.
        self._free_sbs: list[int] = list(range(num_superblocks - 1, -1, -1))
        # Pre-reserved shared region: one superblock worth of frames backed by
        # a memfd; every SHARED_REMAP'd dead superblock maps onto it.
        self._shared_fd = -1
        if strategy is ReleaseStrategy.SHARED_REMAP:
            self._shared_fd = os.memfd_create("repro-oa-shared")
            os.ftruncate(self._shared_fd, superblock_size)
        # Striped locks emulating word-level CAS on arena memory (CPython has
        # no native CAS; the GIL makes this faithful to TSO semantics).
        self._stripes = [threading.Lock() for _ in range(256)]
        # stats
        self.sb_acquired = 0
        self.sb_released = 0
        self.remap_syscalls = 0

    # -- superblock lifecycle -------------------------------------------------

    def acquire_superblock(self) -> int:
        """Return the base offset of a fresh superblock ("request from OS")."""
        with self._lock:
            if not self._free_sbs:
                raise MemoryError("arena exhausted (no free superblocks)")
            idx = self._free_sbs.pop()
            self.sb_acquired += 1
        return idx * self.sb_size

    def release_superblock(self, base_off: int, persistent: bool) -> None:
        """Release an empty superblock.

        Non-persistent: frames dropped and the range returns to the free
        stack (the classic malloc→OS path).  Persistent: the configured
        strategy runs and the range is NOT returned here — the caller keeps
        the (still readable) range alive inside a mapped-descriptor pool
        (paper §3.2 recycles the virtual range via descriptor recycling).
        """
        assert base_off % self.sb_size == 0
        if not persistent:
            self._mm.madvise(mmap.MADV_DONTNEED, base_off, self.sb_size)
            with self._lock:
                self._free_sbs.append(base_off // self.sb_size)
                self.sb_released += 1
            return
        if self.strategy is ReleaseStrategy.KEEP:
            return  # frames retained; reusable by the process, not the OS
        if self.strategy is ReleaseStrategy.MADVISE:
            self._mm.madvise(mmap.MADV_DONTNEED, base_off, self.sb_size)
            return
        # SHARED_REMAP: map the dead range onto the single shared region.
        # PROT_WRITE included: optimistic DWCAS (VBR-style, paper §3.2) may
        # issue write-intent to reclaimed memory; under the shared mapping
        # that dirties the one shared frame (whose contents are garbage by
        # contract) instead of faulting in a private frame per page — the
        # leak-freedom property the paper claims for this method.
        res = _libc.mmap(
            self._base + base_off,
            self.sb_size,
            PROT_READ | PROT_WRITE,
            MAP_SHARED | MAP_FIXED,
            self._shared_fd,
            0,
        )
        if res == ctypes.c_void_p(-1).value or res is None:
            raise OSError(ctypes.get_errno(), "mmap(MAP_FIXED|MAP_SHARED) failed")
        self.remap_syscalls += 1

    def prepare_reuse(self, base_off: int) -> None:
        """Make a previously released persistent range writable again.

        KEEP/MADVISE need nothing (CoW faults frames back in on write);
        SHARED_REMAP replaces the shared window with fresh anonymous memory —
        one syscall regardless of the shared-region granularity (paper §3.2).
        """
        if self.strategy is not ReleaseStrategy.SHARED_REMAP:
            return
        res = _libc.mmap(
            self._base + base_off,
            self.sb_size,
            PROT_READ | PROT_WRITE,
            MAP_PRIVATE | MAP_FIXED | MAP_ANONYMOUS,
            -1,
            0,
        )
        if res == ctypes.c_void_p(-1).value or res is None:
            raise OSError(ctypes.get_errno(), "mmap(MAP_FIXED|MAP_PRIVATE) failed")
        self.remap_syscalls += 1

    # -- memory access --------------------------------------------------------

    def read_u64(self, off: int) -> int:
        """Read 8 little-endian bytes (valid even after any release)."""
        return int.from_bytes(self.view[off : off + 8], "little")

    def write_u64(self, off: int, val: int) -> None:
        """Write 8 little-endian bytes at ``off``."""
        self.view[off : off + 8] = (val & (2**64 - 1)).to_bytes(8, "little")

    def cas_u64(self, off: int, expected: int, new: int) -> bool:
        """CAS on 8 arena bytes (emulated; see ``core.atomic``)."""
        with self._stripes[(off >> 4) & 0xFF]:
            if self.read_u64(off) == expected:
                self.write_u64(off, new)
                return True
            return False

    def cas_u64_hw(self, off: int, expected: int, new: int) -> bool:
        """CAS with *hardware* write-intent semantics: a real lock-prefixed
        CAS needs the cacheline writable even when the compare FAILS, so it
        dirties the page either way (paper §3.2: this is why VBR's DWCAS on
        reclaimed memory faults CoW frames back in under MADV_DONTNEED —
        memory leak — but not under the shared mapping)."""
        with self._stripes[(off >> 4) & 0xFF]:
            cur = self.read_u64(off)
            if cur == expected:
                self.write_u64(off, new)
                return True
            self.write_u64(off, cur)  # write-intent touch: dirties the page
            return False

    # -- measurement -----------------------------------------------------------

    def _smaps_field(self, field: str, off: int, length: int | None) -> int:
        """Sum a /proc/self/smaps field (KiB) over mappings in the range."""
        length = self.total - off if length is None else length
        lo = self._base + off
        hi = lo + length
        total = 0
        cur_overlap = 0.0
        with open("/proc/self/smaps") as f:
            for line in f:
                if "-" in line.split(" ", 1)[0] and line[0] in "0123456789abcdef":
                    try:
                        rng, _ = line.split(" ", 1)
                        a, b = (int(x, 16) for x in rng.split("-"))
                    except ValueError:
                        continue
                    span = max(0, min(b, hi) - max(a, lo))
                    cur_overlap = span / (b - a) if b > a else 0.0
                elif line.startswith(field + ":") and cur_overlap > 0:
                    total += int(int(line.split()[1]) * cur_overlap)
                    cur_overlap = 0.0
        return total

    def resident_pages(self, off: int = 0, length: int | None = None) -> int:
        """Physically resident pages in [off, off+length), measured as smaps
        **Pss** (proportional set size).

        Why not mincore(2): on this kernel it reports MADV_DONTNEED'ed anon
        pages as resident.  Why not Rss: the paper itself observes (§3.2)
        that under the shared-remap method "the memory statistics go
        haywire" — Linux counts the ONE shared frame once per mapping in
        Rss.  Pss divides shared frames by their mapper count, so N dead
        superblocks over one frame cost ~one frame, which is the physical
        truth the paper's argument rests on.  ``resident_rss_pages`` exposes
        the haywire number for the reproduction of that observation.
        """
        return (self._smaps_field("Pss", off, length) * 1024) // PAGE_SIZE

    def resident_rss_pages(self, off: int = 0, length: int | None = None) -> int:
        """Rss-based residency — the 'haywire' number under SHARED_REMAP
        (each mapping of the one shared frame counts fully; paper §3.2)."""
        return (self._smaps_field("Rss", off, length) * 1024) // PAGE_SIZE

    def resident_bytes(self, off: int = 0, length: int | None = None) -> int:
        """Physically resident bytes in the range (Pss-based)."""
        return self.resident_pages(off, length) * PAGE_SIZE

    def close(self) -> None:
        """Unmap the arena and close the shared-frame memfd."""
        self.view.release()
        self._mm.close()
        if self._shared_fd >= 0:
            os.close(self._shared_fd)


class LargeAllocation:
    """Direct-mapped allocation above the largest size class (paper §4).

    These bypass the heap entirely; ``palloc`` refuses them — the paper
    restricts persistent allocation to size-class sizes.
    """

    def __init__(self, nbytes: int):
        self.nbytes = nbytes
        self._mm = mmap.mmap(-1, nbytes)
        self.view = memoryview(self._mm)

    def close(self) -> None:
        """Unmap the direct-mapped allocation."""
        self.view.release()
        self._mm.close()
