"""LRMalloc-style size classes.

LRMalloc serves every allocation up to 16KiB from a size class; larger
requests bypass the heap and map their own superblock (paper §4).  The table
below mirrors jemalloc-style spacing (multiples of 16 up to 128, then four
sub-steps per power of two), which is what LRMalloc uses.
"""

from __future__ import annotations

MAX_SZ: int = 16 * 1024  # largest size-class allocation (16KiB, paper §4)
MIN_SZ: int = 16


def _build_table() -> list[int]:
    sizes = [16 * i for i in range(1, 9)]  # 16..128 step 16
    lo = 128
    while sizes[-1] < MAX_SZ:
        step = lo // 4
        for k in range(1, 5):
            s = lo + k * step
            if s > MAX_SZ:
                break
            sizes.append(s)
        lo *= 2
    return sizes


SIZE_CLASSES: tuple[int, ...] = tuple(_build_table())
NUM_CLASSES: int = len(SIZE_CLASSES)

# Dense lookup: requested size (rounded up to 16) -> class index.
_LUT: list[int] = []


def _build_lut() -> None:
    ci = 0
    for sz16 in range(0, MAX_SZ + 1, 16):
        while SIZE_CLASSES[ci] < sz16:
            ci += 1
        _LUT.append(ci)


_build_lut()


def size_to_class(nbytes: int) -> int:
    """Size-class index serving ``nbytes``.  Raises for large allocations."""
    if nbytes > MAX_SZ:
        raise ValueError(f"{nbytes} exceeds the largest size class {MAX_SZ}")
    if nbytes < 1:
        nbytes = 1
    return _LUT[(nbytes + 15) // 16]


def class_block_size(ci: int) -> int:
    """Block size in bytes of size class ``ci``."""
    return SIZE_CLASSES[ci]
