"""Jit'd public wrappers for the kernels.

``impl`` selection:
- "pallas"    — pl.pallas_call compiled for TPU (the production path)
- "interpret" — same kernel body executed in Python on CPU (correctness)
- "ref"       — the pure-jnp oracle (fast on CPU; used by the serving engine
                in this container)
"""

from __future__ import annotations

import jax

from .paged_attention import paged_attention_pallas
from .ref import paged_attention_ref


def paged_attention(q, kv, block_tables, lengths, *, impl: str = "ref",
                    pages_per_compute_block: int = 1):
    """Decode attention over the paged pool.

    q [B, Hq, D]; kv {'k','v': [P, page, Hkv, D]}; block_tables [B, max_pages];
    lengths [B].  Returns [B, Hq, D].

    ``pages_per_compute_block`` tiles the Pallas grid: each grid step fetches
    that many KV pages and runs one set of MXU dots over the combined
    (ppcb*page_size, Hkv*D) tile (ignored by the jnp reference).
    """
    if impl == "ref":
        return paged_attention_ref(q, kv["k"], kv["v"], block_tables, lengths)
    page_size = kv["k"].shape[1]
    n_kv_heads = kv["k"].shape[2]
    return paged_attention_pallas(
        q, kv["k"], kv["v"], block_tables, lengths,
        page_size=page_size, n_kv_heads=n_kv_heads,
        pages_per_compute_block=pages_per_compute_block,
        interpret=(impl == "interpret"),
    )
