"""Jit'd public wrappers for the kernels.

``impl`` selection:
- "pallas"    — pl.pallas_call compiled for TPU (the production path)
- "interpret" — same kernel body executed in Python on CPU (correctness)
- "ref"       — the pure-jnp oracle (fast on CPU; used by the serving engine
                in this container)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .paged_attention import paged_attention_pallas, paged_attention_sharded
from .ref import paged_attention_chunked_ref, paged_attention_ref


def paged_attention(q, kv, block_tables, lengths, *, impl: str = "ref",
                    pages_per_compute_block: int = 1, chunk_lens=None,
                    mesh=None):
    """Decode or chunked-prefill attention over the paged pool.

    q [B, Hq, D] (decode: one query per row) or [B, C, Hq, D] (chunk: C
    queries per row with in-chunk causal masking); kv {'k','v': [P, page,
    Hkv, D]}; block_tables [B, max_pages]; lengths [B] — the TOTAL valid KV
    length per row including any tokens the chunk just appended.
    ``chunk_lens`` [B] int32 is each row's live query count (rows finishing
    mid-chunk, decode rows in a mixed batch); None means every query slot is
    live.  Returns the same rank as q.

    ``pages_per_compute_block`` tiles the Pallas grid: each grid step fetches
    that many KV pages and runs one set of MXU dots over the combined
    (ppcb*page_size, Hkv*D) tile (ignored by the jnp reference).

    ``mesh`` (tensor-parallel serving): the jnp reference needs nothing —
    GSPMD partitions it from the head-sharded arena layout — but a
    ``pallas_call`` has no partitioning rule, so the pallas/interpret impls
    route through ``paged_attention_sharded`` (``shard_map`` per-shard head
    slabs) whenever the KV-head count divides the mesh's 'model' axis.
    """
    if q.ndim == 3:
        # decode form: one query per row, classic ``pos < lengths`` mask —
        # chunk_lens is meaningless here and is dropped in EVERY impl so
        # ref/interpret/pallas can never silently disagree
        chunk_lens = None
    if impl == "ref":
        if q.ndim == 3:
            return paged_attention_ref(q, kv["k"], kv["v"], block_tables,
                                       lengths)
        if chunk_lens is None:
            chunk_lens = jnp.full((q.shape[0],), q.shape[1], jnp.int32)
        return paged_attention_chunked_ref(q, kv["k"], kv["v"], block_tables,
                                           lengths, chunk_lens)
    page_size = kv["k"].shape[1]
    n_kv_heads = kv["k"].shape[2]
    if (mesh is not None and mesh.shape.get("model", 1) > 1
            and n_kv_heads % mesh.shape["model"] == 0):
        return paged_attention_sharded(
            q, kv["k"], kv["v"], block_tables, lengths, mesh=mesh,
            page_size=page_size, n_kv_heads=n_kv_heads,
            pages_per_compute_block=pages_per_compute_block,
            interpret=(impl == "interpret"), chunk_lens=chunk_lens,
        )
    return paged_attention_pallas(
        q, kv["k"], kv["v"], block_tables, lengths,
        page_size=page_size, n_kv_heads=n_kv_heads,
        pages_per_compute_block=pages_per_compute_block,
        interpret=(impl == "interpret"), chunk_lens=chunk_lens,
    )


def speculative_accept(target_toks, chunk_toks, draft_lens):
    """On-device accept/reject scan for speculative decoding (fused-step
    building block; oracle: ``repro.kernels.ref.speculative_accept_ref``).

    The chunk axis carries a draft: slot 0's input is the row's last
    committed token and slots 1..dlens are optimistic draft tokens.  The
    verifier's greedy argmax at slot j (``target_toks[:, j]``) is what the
    model WOULD emit after the inputs up to slot j — so draft j+1 stands
    exactly when ``target_toks[:, j] == chunk_toks[:, j+1]``.  The longest
    accepted prefix is a cumulative-product scan over that match vector
    (the first mismatch zeroes everything after it), masked to each row's
    live draft count.  This is the sequence-axis version of the pool's
    ``validate_and_commit``: one vectorized validation pass decides how
    much optimistic work commits, and everything past the first failure is
    discarded without ever having blocked the optimistic path.

    target_toks [B, C] int32; chunk_toks [B, C] int32; draft_lens [B] int32
    (0..C−1).  Returns n_acc [B] int32 in [0, draft_lens].
    """
    C = target_toks.shape[1]
    j = jnp.arange(max(C - 1, 1), dtype=jnp.int32)[: C - 1]
    match = (target_toks[:, : C - 1] == chunk_toks[:, 1:]) \
        & (j[None, :] < draft_lens[:, None])
    return jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1),
                   axis=1).astype(jnp.int32)
