"""Pallas TPU kernel: batched KV append into the paged pool.

The serving engine's second hot spot: writing one token's K/V for every
running sequence into its block-table-addressed page slot.  The jnp path
(`pagepool.append_kv`) lowers to a scatter that on TPU reads-modifies-writes
whole pages; this kernel DMAs exactly one (n_kv_heads, head_dim) row per
sequence, with the page id and intra-page slot resolved from scalar-prefetch
memory (the pagemap-in-SMEM trick shared with the paged-attention kernel).

Writes go only to scheduler-pinned pages (the hazard-pointer half of OA):
a -1 page id (preempted mid-batch) skips the write entirely rather than
faulting — freed pages must never be written, only read.

Grid: (B,).  Block mapping: the kv page arrays are indexed by the page id
for sequence b; the output aliases the input (in-place page update).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(pages_ref, slots_ref, k_new_ref, v_new_ref, k_ref, v_ref,
            ko_ref, vo_ref, *, page_size: int):
    b = pl.program_id(0)
    slot = slots_ref[b]
    live = pages_ref[b] >= 0

    # copy-through (grid steps own distinct pages; aliasing elides the copy
    # on the real backend, interpret mode needs the explicit assignment)
    ko_ref[...] = k_ref[...]
    vo_ref[...] = v_ref[...]

    @pl.when(live)
    def _write():
        ko_ref[0, slot] = k_new_ref[0]
        vo_ref[0, slot] = v_new_ref[0]


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def kv_append_pallas(kv, block_tables, lengths, k_new, v_new, *,
                     page_size: int, interpret: bool = True):
    """kv {'k','v': [P, page, Hkv, D]}; block_tables [B, max_pages];
    lengths [B] (new token position); k_new/v_new [B, Hkv, D]."""
    B = lengths.shape[0]
    P, page, Hkv, D = kv["k"].shape
    page_idx = lengths // page_size
    slots = (lengths % page_size).astype(jnp.int32)
    pages = jnp.take_along_axis(block_tables, page_idx[:, None], axis=1)[:, 0]

    def page_map(b, pg, sl):
        return (jnp.maximum(pg[b], 0), 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Hkv, D), lambda b, pg, sl: (b, 0, 0)),
            pl.BlockSpec((1, Hkv, D), lambda b, pg, sl: (b, 0, 0)),
            pl.BlockSpec((1, page, Hkv, D), page_map),
            pl.BlockSpec((1, page, Hkv, D), page_map),
        ],
        out_specs=[
            pl.BlockSpec((1, page, Hkv, D), page_map),
            pl.BlockSpec((1, page, Hkv, D), page_map),
        ],
    )
    kern = functools.partial(_kernel, page_size=page_size)
    ko, vo = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(kv["k"].shape, kv["k"].dtype),
            jax.ShapeDtypeStruct(kv["v"].shape, kv["v"].dtype),
        ],
        input_output_aliases={4: 0, 5: 1},  # indices include prefetch scalars
        interpret=interpret,
    )(pages, slots, k_new, v_new, kv["k"], kv["v"])
    return {"k": ko, "v": vo}
