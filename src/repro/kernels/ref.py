"""Pure-jnp oracles for the Pallas kernels.

``paged_attention_ref`` is the reference semantics for decode attention over
the versioned page pool: gather pages through the block table (reads through
freed pages are safe — the arena is persistent), mask to the live length,
online softmax.  The Pallas kernel must match this bit-for-bit in fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths):
    """q [B, Hq, D]; k_pages/v_pages [P, page, Hkv, D];
    block_tables [B, max_pages] int32 (−1 = unmapped); lengths [B] int32.
    Returns [B, Hq, D] (q.dtype)."""
    B, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    G = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))

    def one(qb, bt, ln):
        pages = jnp.maximum(bt, 0)
        k = k_pages[pages].reshape(max_pages * page, Hkv, D)
        v = v_pages[pages].reshape(max_pages * page, Hkv, D)
        qg = qb.reshape(Hkv, G, D).astype(jnp.float32)
        s = jnp.einsum("hgd,shd->hgs", qg, k.astype(jnp.float32)) * scale
        pos = jnp.arange(max_pages * page)
        s = jnp.where(pos[None, None, :] < ln, s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("hgs,shd->hgd", p, v.astype(jnp.float32))
        return o.reshape(Hq, D)

    return jax.vmap(one)(q, block_tables, lengths).astype(q.dtype)
