"""Pure-jnp oracles for the Pallas kernels.

``paged_attention_ref`` is the reference semantics for decode attention over
the versioned page pool: gather pages through the block table (reads through
freed pages are safe — the arena is persistent), mask to the live length,
online softmax.  ``paged_attention_chunked_ref`` generalizes it along the
sequence axis for chunked prefill: a chunk of C query tokens attends the
same paged KV with an in-chunk causal mask (query j of a row whose chunk
holds ``chunk_lens`` live tokens sees key positions
``< min(lengths - chunk_lens + j + 1, lengths)``), so one call covers C
prompt tokens where decode needed C dispatches.  The Pallas kernel must
match these bit-for-bit in fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths):
    """q [B, Hq, D]; k_pages/v_pages [P, page, Hkv, D];
    block_tables [B, max_pages] int32 (−1 = unmapped); lengths [B] int32.
    Returns [B, Hq, D] (q.dtype)."""
    B, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    G = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))

    def one(qb, bt, ln):
        pages = jnp.maximum(bt, 0)
        k = k_pages[pages].reshape(max_pages * page, Hkv, D)
        v = v_pages[pages].reshape(max_pages * page, Hkv, D)
        qg = qb.reshape(Hkv, G, D).astype(jnp.float32)
        s = jnp.einsum("hgd,shd->hgs", qg, k.astype(jnp.float32)) * scale
        pos = jnp.arange(max_pages * page)
        s = jnp.where(pos[None, None, :] < ln, s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("hgs,shd->hgd", p, v.astype(jnp.float32))
        return o.reshape(Hq, D)

    return jax.vmap(one)(q, block_tables, lengths).astype(q.dtype)


def paged_attention_chunked_ref(q, k_pages, v_pages, block_tables, lengths,
                                chunk_lens):
    """Chunked-prefill oracle: C query tokens per row in one pass.

    q [B, C, Hq, D]; k_pages/v_pages [P, page, Hkv, D]; block_tables
    [B, max_pages] int32 (−1 = unmapped); lengths [B] int32 is the TOTAL
    valid KV length per row *including* the chunk's freshly appended tokens;
    chunk_lens [B] int32 (1..C) is how many of the C query slots are live.
    Query j sits at global position ``lengths - chunk_lens + j``, so its
    causal horizon is ``pos < lengths - chunk_lens + j + 1``; padded query
    slots (j >= chunk_lens) fall back to the full ``pos < lengths`` view —
    their output is finite but unused (the fused step samples only from
    slot ``chunk_lens - 1``).  Returns [B, C, Hq, D] (q.dtype).

    Fully-masked queries (e.g. lengths == 0 rows) return zeros rather than
    NaN: the softmax is the guarded online form the Pallas kernel uses.
    """
    B, C, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    G = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))

    def one(qb, bt, ln, cn):
        pages = jnp.maximum(bt, 0)
        k = k_pages[pages].reshape(max_pages * page, Hkv, D)
        v = v_pages[pages].reshape(max_pages * page, Hkv, D)
        qg = qb.reshape(C, Hkv, G, D).astype(jnp.float32)
        s = jnp.einsum("chgd,shd->chgs", qg, k.astype(jnp.float32)) * scale
        pos = jnp.arange(max_pages * page)
        qpos = ln - cn + jnp.arange(C)  # global position of query j
        limit = jnp.minimum(qpos + 1, ln)  # in-chunk causal horizon
        mask = (pos[None, :] < limit[:, None]) & (bt[pos // page] >= 0)[None, :]
        s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
        m = jnp.max(s, axis=-1)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.where(mask[:, None, None, :], jnp.exp(s - m_safe[..., None]), 0.0)
        l = jnp.maximum(jnp.sum(p, axis=-1), 1e-30)
        o = jnp.einsum("chgs,shd->chgd", p / l[..., None],
                       v.astype(jnp.float32))
        return o.reshape(C, Hq, D)

    return jax.vmap(one)(q, block_tables, lengths, chunk_lens).astype(q.dtype)


def speculative_accept_ref(target_toks, chunk_toks, draft_lens):
    """Python-loop oracle for the speculative accept scan (numpy-friendly).

    target_toks [B, C] int — the verifier's greedy prediction at every chunk
    slot; chunk_toks [B, C] int — the slot INPUTS (slot 0 the row's last
    committed token, slots 1..dlens its drafts); draft_lens [B] int (0..C−1).
    Returns n_acc [B] int32: per row, the longest prefix ``j < draft_lens``
    with ``target_toks[j] == chunk_toks[j + 1]`` — draft j+1 is accepted iff
    the model, fed the accepted prefix, would itself have emitted it.  Pure
    host semantics the fused scan (``ops.speculative_accept``) must match
    exactly; used by the kernel parity tests and the property tests.
    """
    import numpy as np
    t = np.asarray(target_toks)
    c = np.asarray(chunk_toks)
    d = np.asarray(draft_lens)
    B, C = t.shape
    out = np.zeros((B,), np.int32)
    for b in range(B):
        n = 0
        while n < min(int(d[b]), C - 1) and t[b, n] == c[b, n + 1]:
            n += 1
        out[b] = n
    return out
