# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
from .ops import paged_attention
from .ref import paged_attention_ref
from .kv_append import kv_append_pallas

__all__ = ["paged_attention", "paged_attention_ref", "kv_append_pallas"]
