"""Pallas TPU kernel: decode + chunked-prefill attention over the versioned
page pool.

This is the compute hot-spot of the paper's device-side adaptation: the
optimistic reader.  It walks a sequence's block table in compute blocks of
``pages_per_compute_block`` KV pages, DMA'ing each page HBM→VMEM exactly
once and keeping the flash accumulator state (m, l, acc) in VMEM scratch —
the jnp reference path instead materializes the gathered [S, Hkv, D] cache
in HBM (2× traffic on the dominant term of the decode roofline; see
EXPERIMENTS.md §Perf).

The query side carries a **chunk axis**: q is [B, C, Hq, D] where C is the
chunk size (C = 1 is the decode special case — the [B, Hq, D] form is
accepted and squeezed back on return).  A chunked-prefill step appends C
prompt tokens and attends them all in ONE kernel launch; the paper's
amortize-the-validation argument applied along the sequence axis (one
dispatch, one OA validation for C tokens instead of C of each).

TPU mapping:
- grid = (batch, ceil(max_pages / pages_per_compute_block)); the block table
  rides in scalar-prefetch memory (SMEM) so the ``index_map`` can translate
  virtual page slots to physical page ids *before* the DMAs are issued — the
  pagemap lookup of LRMalloc, done by the DMA engine.
- Each grid step assembles a (ppcb*page_size, Hkv*D) KV tile from ``ppcb``
  independently-mapped pages (one BlockSpec per page within the block — the
  pages are scattered in the arena, so each needs its own translation), then
  issues ONE set of MXU dots for all C queries over the whole tile.  Larger
  ``ppcb`` ⇒ fewer grid steps, fewer accumulator round-trips, larger dots —
  the same batching-of-validation amortization OA applies to reclamation.
- **In-chunk causal mask**: ``lengths[b]`` is the row's TOTAL valid KV
  length including this step's appended chunk; ``chunk_lens[b]`` (1..C) is
  how many of the C query slots are live.  Query j sits at global position
  ``lengths - chunk_lens + j`` and sees ``pos < lengths - chunk_lens + j +
  1``; padded slots (j >= chunk_lens — rows finishing mid-chunk, decode
  rows inside a mixed batch) fall back to the full ``pos < lengths`` view,
  staying finite while their output is discarded.
- ``pl.when`` skips the COMPUTE (dots, softmax accumulation, scratch
  round-trips) for blocks that are entirely past ``lengths[b]`` or fully
  unmapped (every table entry < 0).  Note the BlockSpec DMAs are still
  issued for skipped blocks — index_maps run regardless of kernel-body
  predicates — so ragged padding saves FLOPs and accumulator traffic, not
  HBM reads.
- Freed pages remain mapped in the persistent arena, so a stale block table
  entry fetches garbage *safely*; the scheduler's version check discards the
  result (OA semantics — reads validated after the fact).
- Block shapes: page_size and Hkv*D should be multiples of (8, 128) for
  MXU/VREG alignment; q is (C, Hkv*G, D) = (C, Hq, D).

Weak spots the sweep tests cover: GQA grouping, ragged lengths mid-page,
unmapped (-1) table entries, page_size not dividing length, max_pages not
divisible by pages_per_compute_block (padded with -1 slots), chunks
straddling page boundaries, and rows finishing mid-chunk
(chunk_lens < C).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    # scalar-prefetch
    block_tables_ref,  # [B, nblocks*ppcb] (SMEM)
    lengths_ref,  # [B] (SMEM) — total valid length incl. the chunk
    chunk_lens_ref,  # [B] (SMEM) — live query slots (1..C)
    # blocked inputs: q, then ppcb k-page refs, then ppcb v-page refs
    q_ref,  # [1, C, Hq, D]
    *refs,
    page_size: int,
    n_kv_heads: int,
    ppcb: int,
):
    k_refs = refs[:ppcb]  # each [1, page, Hkv, D]
    v_refs = refs[ppcb : 2 * ppcb]
    o_ref = refs[2 * ppcb]  # [1, C, Hq, D]
    m_ref, l_ref, acc_ref = refs[2 * ppcb + 1 :]  # VMEM scratch, C axis first

    b = pl.program_id(0)
    i = pl.program_id(1)
    nb = pl.num_programs(1)
    span = ppcb * page_size
    C = q_ref.shape[1]

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # per-page mapped bits from SMEM — drive both the skip predicate and the
    # position mask (an unmapped page inside the block contributes nothing)
    mapped = jnp.stack(
        [block_tables_ref[b, i * ppcb + j] >= 0 for j in range(ppcb)]
    )
    start = i * span
    block_live = (start < lengths_ref[b]) & jnp.any(mapped)

    @pl.when(block_live)
    def _compute():
        q = q_ref[0]  # [C, Hq, D]
        k = jnp.concatenate([r[0] for r in k_refs], axis=0)  # [span, Hkv, D]
        v = jnp.concatenate([r[0] for r in v_refs], axis=0)
        Hq, D = q.shape[1], q.shape[2]
        G = Hq // n_kv_heads
        qg = q.reshape(C, n_kv_heads, G, D).astype(jnp.float32)
        # [C, Hkv, G, span] — one MXU dot per kv head for all C queries
        s = jnp.einsum("chgd,phd->chgp", qg, k.astype(jnp.float32))
        s = s * (1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32)))

        pos = start + jax.lax.iota(jnp.int32, span)
        # in-chunk causal horizon: query j (global position
        # lengths - chunk_lens + j) sees pos < that position + 1; padded
        # slots (j >= chunk_lens) clamp to the full pos < lengths view
        qpos = lengths_ref[b] - chunk_lens_ref[b] + jax.lax.iota(jnp.int32, C)
        limit = jnp.minimum(qpos + 1, lengths_ref[b])
        live = (pos[None, :] < limit[:, None]) & \
            jnp.repeat(mapped, page_size)[None, :]  # [C, span]
        s = jnp.where(live[:, None, None, :], s, -jnp.inf)

        m_prev = m_ref[...].reshape(C, n_kv_heads, G)
        l_prev = l_ref[...].reshape(C, n_kv_heads, G)
        acc_prev = acc_ref[...].reshape(C, n_kv_heads, G, D)

        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(live[:, None, None, :],
                      jnp.exp(s - m_safe[..., None]), 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("chgp,phd->chgd", p, v.astype(jnp.float32))
        acc_new = acc_prev * alpha[..., None] + pv

        m_ref[...] = m_new.reshape(C, Hq)
        l_ref[...] = l_new.reshape(C, Hq)
        acc_ref[...] = acc_new.reshape(C, Hq, D)

    @pl.when(i == nb - 1)
    def _finish():
        Hq, D = o_ref.shape[2], o_ref.shape[3]
        G = Hq // n_kv_heads
        l = jnp.maximum(l_ref[...].reshape(C, n_kv_heads, G), 1e-30)
        out = acc_ref[...].reshape(C, n_kv_heads, G, D) / l[..., None]
        o_ref[0] = out.reshape(C, Hq, D).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("page_size", "n_kv_heads", "pages_per_compute_block",
                     "interpret"),
)
def paged_attention_pallas(q, k_pages, v_pages, block_tables, lengths, *,
                           page_size: int, n_kv_heads: int,
                           pages_per_compute_block: int = 1,
                           interpret: bool = True, chunk_lens=None):
    """q [B, Hq, D] (decode) or [B, C, Hq, D] (chunk) -> same shape back.

    ``lengths`` is the total valid KV length per row (including the chunk's
    appended tokens); ``chunk_lens`` [B] int32 gives each row's live query
    count for the in-chunk causal mask (None = every slot live — for the
    decode form that is the classic single-query mask).  See the module
    docstring for layout rules.
    """
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, C, Hq, D = q.shape
    if chunk_lens is None:
        chunk_lens = jnp.full((B,), C, jnp.int32)
    ppcb = max(int(pages_per_compute_block), 1)
    max_pages = block_tables.shape[1]
    nblocks = -(-max_pages // ppcb)
    if nblocks * ppcb != max_pages:
        block_tables = jnp.pad(
            block_tables, ((0, 0), (0, nblocks * ppcb - max_pages)),
            constant_values=-1)

    def page_map(j):
        # each of the block's ppcb pages gets its own virtual→physical
        # translation (they are scattered in the arena)
        def m(b, i, bt, ln, cl):
            return (jnp.maximum(bt[b, i * ppcb + j], 0), 0, 0, 0)
        return m

    kv_spec = lambda j: pl.BlockSpec((1, page_size, n_kv_heads, D), page_map(j))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, nblocks),
        in_specs=(
            [pl.BlockSpec((1, C, Hq, D), lambda b, i, bt, ln, cl: (b, 0, 0, 0))]
            + [kv_spec(j) for j in range(ppcb)]
            + [kv_spec(j) for j in range(ppcb)]
        ),
        out_specs=pl.BlockSpec((1, C, Hq, D),
                               lambda b, i, bt, ln, cl: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, Hq), jnp.float32),
            pltpu.VMEM((C, Hq), jnp.float32),
            pltpu.VMEM((C, Hq, D), jnp.float32),
        ],
    )
    kern = functools.partial(_kernel, page_size=page_size,
                             n_kv_heads=n_kv_heads, ppcb=ppcb)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, Hq, D), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, chunk_lens, q,
      *([k_pages] * ppcb), *([v_pages] * ppcb))
    return out[:, 0] if squeeze else out


def paged_attention_sharded(q, k_pages, v_pages, block_tables, lengths, *,
                            mesh, page_size: int, n_kv_heads: int,
                            pages_per_compute_block: int = 1,
                            interpret: bool = True, chunk_lens=None):
    """Tensor-parallel Pallas dispatch: ``shard_map`` over the mesh's 'model'
    axis, one kernel launch per shard on its LOCAL head slab.

    GSPMD cannot partition a ``pallas_call`` (no partitioning rule), so the
    TP serving path wraps the kernel manually: q shards its ``Hq`` axis and
    the KV arena its ``Hkv`` axis (both kv-head-major, so GQA groups never
    straddle shards — q reshapes to ``[C, Hkv, G, D]`` inside the kernel),
    while block tables / lengths / chunk_lens ride in replicated.  Attention
    is embarrassingly parallel over KV-head groups: no collective here — the
    cross-shard ``psum`` happens at the row-parallel ``wo`` matmul the
    caller runs on the sharded output.  ``n_kv_heads`` is the GLOBAL count;
    it must divide the 'model' axis size (callers fall back to the unsharded
    kernel otherwise).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    tp = mesh.shape["model"]
    if n_kv_heads % tp != 0:
        raise ValueError(f"n_kv_heads={n_kv_heads} not divisible by tp={tp}")
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, C = q.shape[:2]
    if chunk_lens is None:
        chunk_lens = jnp.full((B,), C, jnp.int32)
    heads = P(None, None, "model", None)  # q [B,C,Hq,D] / kv [P,page,Hkv,D]
    rep = P()

    def local(bt, ln, cl, qs, ks, vs):
        return paged_attention_pallas(
            qs, ks, vs, bt, ln, page_size=page_size,
            n_kv_heads=n_kv_heads // tp,
            pages_per_compute_block=pages_per_compute_block,
            interpret=interpret, chunk_lens=cl)

    out = shard_map(
        local, mesh=mesh,
        in_specs=(rep, rep, rep, heads, heads, heads),
        out_specs=heads, check_rep=False,
    )(block_tables, lengths, chunk_lens, q, k_pages, v_pages)
    return out[:, 0] if squeeze else out
