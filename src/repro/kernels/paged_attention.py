"""Pallas TPU kernel: decode attention over the versioned page pool.

This is the compute hot-spot of the paper's device-side adaptation: the
optimistic reader.  It walks a sequence's block table page-by-page, DMA'ing
each KV page HBM→VMEM exactly once and keeping the flash accumulator state
(m, l, acc) in VMEM scratch — the jnp reference path instead materializes
the gathered [S, Hkv, D] cache in HBM (2× traffic on the dominant term of
the decode roofline; see EXPERIMENTS.md §Perf).

TPU mapping:
- grid = (batch, max_pages); the block table rides in scalar-prefetch memory
  (SMEM) so the ``index_map`` can translate virtual page slots to physical
  page ids *before* the DMA is issued — the pagemap lookup of LRMalloc, done
  by the DMA engine.
- Freed pages remain mapped in the persistent arena, so a stale block table
  entry fetches garbage *safely*; the scheduler's version check discards the
  result (OA semantics — reads validated after the fact).
- Block shapes: KV pages arrive as (page_size, Hkv*D) tiles — page_size and
  Hkv*D should be multiples of (8, 128) for MXU/VREG alignment; q is
  (Hkv*G, D) = (Hq, D).

Weak spots the sweep tests cover: GQA grouping, ragged lengths mid-page,
unmapped (-1) table entries, page_size not dividing length.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    # scalar-prefetch
    block_tables_ref,  # [B, max_pages] (SMEM)
    lengths_ref,  # [B] (SMEM)
    # blocked inputs
    q_ref,  # [1, Hq, D]
    k_ref,  # [1, page, Hkv, D]
    v_ref,  # [1, page, Hkv, D]
    # output
    o_ref,  # [1, Hq, D]
    # VMEM scratch
    m_ref,  # [Hq]
    l_ref,  # [Hq]
    acc_ref,  # [Hq, D]
    *,
    page_size: int,
    n_kv_heads: int,
):
    b = pl.program_id(0)
    i = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # [Hq, D]
    k = k_ref[0]  # [page, Hkv, D]
    v = v_ref[0]
    Hq, D = q.shape
    G = Hq // n_kv_heads
    qg = q.reshape(n_kv_heads, G, D).astype(jnp.float32)
    # [Hkv, G, page] — lowers to one MXU dot per kv head
    s = jnp.einsum("hgd,phd->hgp", qg, k.astype(jnp.float32))
    s = s * (1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32)))

    pos = i * page_size + jax.lax.iota(jnp.int32, page_size)
    live = (pos < lengths_ref[b]) & (block_tables_ref[b, i] >= 0)
    s = jnp.where(live[None, None, :], s, -jnp.inf)

    m_prev = m_ref[...].reshape(n_kv_heads, G)
    l_prev = l_ref[...].reshape(n_kv_heads, G)
    acc_prev = acc_ref[...].reshape(n_kv_heads, G, D)

    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(live[None, None, :], jnp.exp(s - m_safe[..., None]), 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    pv = jnp.einsum("hgp,phd->hgd", p, v.astype(jnp.float32))
    acc_new = acc_prev * alpha[..., None] + pv

    m_ref[...] = m_new.reshape(Hq)
    l_ref[...] = l_new.reshape(Hq)
    acc_ref[...] = acc_new.reshape(Hq, D)

    @pl.when(i == np_ - 1)
    def _finish():
        l = jnp.maximum(l_ref[...].reshape(n_kv_heads, G), 1e-30)
        out = acc_ref[...].reshape(n_kv_heads, G, D) / l[..., None]
        o_ref[0] = out.reshape(Hq, D).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("page_size", "n_kv_heads", "interpret")
)
def paged_attention_pallas(q, k_pages, v_pages, block_tables, lengths, *,
                           page_size: int, n_kv_heads: int, interpret: bool = True):
    """q [B, Hq, D] -> [B, Hq, D].  See module docstring for layout rules."""
    B, Hq, D = q.shape
    max_pages = block_tables.shape[1]

    def page_map(b, i, bt, ln):
        return (jnp.maximum(bt[b, i], 0), 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, i, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, page_size, n_kv_heads, D), page_map),
            pl.BlockSpec((1, page_size, n_kv_heads, D), page_map),
        ],
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, i, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hq,), jnp.float32),
            pltpu.VMEM((Hq,), jnp.float32),
            pltpu.VMEM((Hq, D), jnp.float32),
        ],
    )
    kern = functools.partial(_kernel, page_size=page_size, n_kv_heads=n_kv_heads)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, q, k_pages, v_pages)
