"""Memory-release demo (paper §3.2, Fig. 3): all three strategies side by
side, with actual resident-memory measurements from /proc.

Run: PYTHONPATH=src python examples/reclaim_demo.py
"""

from repro.core import LRMalloc, ReleaseStrategy, OABit, MichaelHashTable

ROWS = []
for strategy in ReleaseStrategy:
    alloc = LRMalloc(num_superblocks=256, superblock_size=64 * 1024,
                     strategy=strategy)
    rec = OABit(alloc, limbo_threshold=64)
    ht = MichaelHashTable(rec, 256)
    ctx = rec.thread_ctx()
    for k in range(1, 20000):
        ht.insert(k, ctx)
    peak = alloc.resident_bytes()
    for k in range(1, 20000):
        ht.delete(k, ctx)
    rec.flush(ctx)
    alloc.flush_all_caches()
    after = alloc.resident_bytes()
    # the ranges must remain readable (OA's contract) even after release
    probe = [alloc.read_u64(off) for off in range(16, 64 * 1024, 4096)]
    ROWS.append((strategy.value, peak >> 10, after >> 10,
                 alloc.stats.persistent_released, len(probe)))
    alloc.close()

print(f"{'strategy':14s} {'peak KiB':>9s} {'after KiB':>10s} {'sb released':>12s} {'reads ok':>9s}")
for r in ROWS:
    print(f"{r[0]:14s} {r[1]:9d} {r[2]:10d} {r[3]:12d} {r[4]:9d}")
print("\nkeep: frames stay with the process (reusable, not returned)")
print("madvise/shared_remap: frames returned to the OS, ranges still readable")

# ---------------------------------------------------------------------------
# The same story on the DEVICE pool: the serving engine's superblock-
# structured KV arena shrinks after a burst — EMPTY superblocks leave
# circulation (versions bumped, the OA warning) and remap on the next burst.

print("\n=== device KV pool: superblock release after a burst ===")

import dataclasses

import jax

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving import PagedServingEngine

cfg = dataclasses.replace(reduced(get_config("olmo-1b")), n_layers=1)
params = build_model(cfg).init(jax.random.PRNGKey(0))
eng = PagedServingEngine(
    cfg, params, num_pages=32, page_size=2, max_batch=4,
    max_pages_per_seq=8, pages_per_superblock=4,
    release_strategy=ReleaseStrategy.MADVISE, min_mapped_superblocks=1)

for prompt in ([1, 2, 3], [4, 5], [6, 7, 8], [9, 10]):  # the burst
    eng.submit(prompt, 8)
eng.run()

s = eng.stats
print(f"after burst:  {s.superblocks_mapped}/{s.superblocks_resident} "
      f"superblocks mapped ({s.mapped_pages} pages)")
released = eng.shrink()
s = eng.stats
print(f"after shrink: {s.superblocks_mapped}/{s.superblocks_resident} "
      f"superblocks mapped ({s.mapped_pages} pages) — "
      f"{released} superblocks released")
r = eng.submit([11, 12, 13], 8)  # the next burst remaps under pressure
eng.run()
s = eng.stats
print(f"next burst:   {s.superblocks_mapped}/{s.superblocks_resident} "
      f"superblocks mapped again ({s.superblocks_remapped} remapped, "
      f"{s.preemptions} preemptions) — request {r.state}")
print("the KV arena itself is palloc'd once: released ranges stay readable,"
      "\nstale optimistic readers fail version validation instead of faulting")
