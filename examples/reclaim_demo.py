"""Memory-release demo (paper §3.2, Fig. 3): all three strategies side by
side, with actual resident-memory measurements from /proc.

Run: PYTHONPATH=src python examples/reclaim_demo.py
"""

from repro.core import LRMalloc, ReleaseStrategy, OABit, MichaelHashTable

ROWS = []
for strategy in ReleaseStrategy:
    alloc = LRMalloc(num_superblocks=256, superblock_size=64 * 1024,
                     strategy=strategy)
    rec = OABit(alloc, limbo_threshold=64)
    ht = MichaelHashTable(rec, 256)
    ctx = rec.thread_ctx()
    for k in range(1, 20000):
        ht.insert(k, ctx)
    peak = alloc.resident_bytes()
    for k in range(1, 20000):
        ht.delete(k, ctx)
    rec.flush(ctx)
    alloc.flush_all_caches()
    after = alloc.resident_bytes()
    # the ranges must remain readable (OA's contract) even after release
    probe = [alloc.read_u64(off) for off in range(16, 64 * 1024, 4096)]
    ROWS.append((strategy.value, peak >> 10, after >> 10,
                 alloc.stats.persistent_released, len(probe)))
    alloc.close()

print(f"{'strategy':14s} {'peak KiB':>9s} {'after KiB':>10s} {'sb released':>12s} {'reads ok':>9s}")
for r in ROWS:
    print(f"{r[0]:14s} {r[1]:9d} {r[2]:10d} {r[3]:12d} {r[4]:9d}")
print("\nkeep: frames stay with the process (reusable, not returned)")
print("madvise/shared_remap: frames returned to the OS, ranges still readable")
