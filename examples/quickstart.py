"""Quickstart: the paper's machinery end-to-end in ~a minute on CPU.

1. Host layer (the faithful reproduction): palloc + OA-VER reclamation over
   a real mmap arena — frees release physical frames while the ranges stay
   readable.
2. Device layer (the TPU adaptation): a paged-KV serving engine whose
   preemption path is optimistic reclamation with version validation, and
   whose prefix cache shares prompt KV pages across requests by refcount.
3. A tiny training run through the same substrate a 72B config would use.

Run: PYTHONPATH=src python examples/quickstart.py

Every demo takes scale arguments so the smoke test
(tests/test_examples.py) can run them near-instantly.
"""

import jax
import numpy as np

from repro.core import (
    LRMalloc, ReleaseStrategy, OAVer, HarrisMichaelList,
)
from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving import PagedServingEngine


def host_layer_demo(n_keys: int = 3000):
    print("== host layer: OA-VER over palloc, frames released to the OS ==")
    alloc = LRMalloc(num_superblocks=128, superblock_size=64 * 1024,
                     strategy=ReleaseStrategy.SHARED_REMAP)
    rec = OAVer(alloc, limbo_threshold=32)
    lst = HarrisMichaelList(rec)
    ctx = rec.thread_ctx()
    for k in range(1, n_keys):
        lst.insert(k, ctx)
    before = alloc.resident_bytes()
    for k in range(1, n_keys):
        lst.delete(k, ctx)
    rec.flush(ctx)
    alloc.flush_all_caches()
    after = alloc.resident_bytes()
    s = rec.stats.snapshot()
    print(f"   resident {before >> 10} KiB -> {after >> 10} KiB after reclaim")
    print(f"   warnings={s['warnings_fired']} restarts={s['reader_restarts']} "
          f"freed={s['nodes_freed']}")
    alloc.close()


def serving_demo(n_requests: int = 5, max_new: int = 8):
    print("== device layer: paged serving with optimistic reclamation ==")
    cfg = reduced(get_config("olmo-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = PagedServingEngine(cfg, params, num_pages=8, page_size=4,
                             max_batch=3, max_pages_per_seq=8)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab, (6,)).tolist(), max_new)
            for _ in range(n_requests)]
    stats = eng.run()
    assert all(r.state == "finished" for r in reqs)
    print(f"   {stats.tokens_committed} tokens, preemptions={stats.preemptions}, "
          f"restarts={stats.reader_restarts}, warnings={stats.warnings_fired}")

    # prefix sharing: the same system prompt across requests is served from
    # the refcounted prefix cache — prefill skipped, pages aliased
    eng2 = PagedServingEngine(cfg, params, num_pages=32, page_size=4,
                              max_batch=3, max_pages_per_seq=8,
                              prefix_cache=True)
    system = rng.integers(0, cfg.vocab, (8,)).tolist()
    reqs2 = [eng2.submit(system + rng.integers(0, cfg.vocab, (2,)).tolist(),
                         max_new)
             for _ in range(n_requests)]
    stats2 = eng2.run()
    assert all(r.state == "finished" for r in reqs2)
    print(f"   prefix cache: hits={stats2.prefix_hits} "
          f"tokens_reused={stats2.prefix_tokens_reused} "
          f"pages_allocated={stats2.pages_allocated} "
          f"(vs {stats.pages_allocated} unshared)")


def train_demo(steps: int = 40):
    print(f"== training substrate (reduced olmo-1b, {steps} steps) ==")
    import repro.launch.train as T
    import argparse
    args = argparse.Namespace(
        arch="olmo-1b", reduced=True, steps=steps, batch=2, seq=64, lr=3e-3,
        seed=0, log_every=10, ckpt_dir=None, ckpt_every=50, fail_at_step=None,
        grad_compression="none")
    T.train(args)


if __name__ == "__main__":
    host_layer_demo()
    serving_demo()
    train_demo()
    print("quickstart OK")
