"""End-to-end training example: a ~20M-param LM for a few hundred steps with
checkpointing and an injected mid-run failure (the restart is automatic).

On a real pod, drop --reduced and pass --arch qwen2-72b etc.; the sharding
rules, data sharding, checkpointing and restart logic are the same code.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses

import repro.launch.train as T
from repro.configs import get_config

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ns = ap.parse_args()
    args = argparse.Namespace(
        arch="olmo-1b", reduced=True, steps=ns.steps, batch=4, seq=128,
        lr=3e-3, seed=0, log_every=25, ckpt_dir=ns.ckpt_dir, ckpt_every=100,
        fail_at_step=ns.steps // 2, grad_compression="bf16", data_source="ramp",
    )
    out = T.train(args)
    assert out["final_loss"] < out["history"][0]["loss"], "loss must decrease"
    print("train_lm OK — loss decreased through an injected failure+restart")
