"""Serving example: batched requests through the paged engine under memory
pressure — preemptions and version-validated restarts happen live.

Run: PYTHONPATH=src python examples/serve_paged.py
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--requests", "12", "--num-pages", "12",
                "--page-size", "8", "--max-batch", "4", "--prompt-len", "10",
                "--max-new", "20"]
    main()
