"""Serving example: batched requests through the paged engine under memory
pressure — preemptions and version-validated restarts happen live — then the
same workload with the refcounted prefix cache: every request carries the
same 8-token system prompt, so later admissions share its KV pages
(refcount += 1) and skip its prefill entirely.

Two overload-era modes ride along (ISSUE 9): ``--stream`` drains through
the streaming generator (tokens print as steps complete, not at drain
end), and ``--trace`` replays a recorded two-class open-loop schedule
against the wall clock — per-class tail latency is reported at the end.

Run: PYTHONPATH=src python examples/serve_paged.py
"""

import os
import tempfile

from repro.launch.serve import main
from repro.serving import dump_trace, synthesize_trace

BASE = ["--requests", "12", "--num-pages", "12", "--page-size", "8",
        "--max-batch", "4", "--prompt-len", "10", "--max-new", "20"]

if __name__ == "__main__":
    print("== no sharing: every prompt distinct, pool under pressure ==")
    main(BASE)
    print("== prefix sharing: common system prompt served from the cache ==")
    stats = main(BASE + ["--prefix-cache", "--shared-prefix", "8",
                         "--num-pages", "24"])
    assert stats.prefix_hits > 0, "shared prompts must hit the prefix index"
    print("== streaming: tokens arrive as steps complete ==")
    main(["--requests", "3", "--num-pages", "24", "--page-size", "8",
          "--max-batch", "2", "--prompt-len", "8", "--max-new", "6",
          "--stream", "--classes", "interactive:0.7,batch:0.3"])
    print("== trace replay: two-class bursty schedule, open loop ==")
    events = synthesize_trace(0, duration_s=2.0, rate_rps=6.0,
                              process="bursty",
                              class_mix={"interactive": 0.7, "batch": 0.3},
                              prompt_mean=8, max_new_mean=6,
                              prompt_cap=16, max_new_cap=8)
    fd, path = tempfile.mkstemp(suffix=".jsonl")
    os.close(fd)
    try:
        dump_trace(events, path)
        stats = main(["--num-pages", "48", "--page-size", "8",
                      "--max-batch", "4", "--trace", path])
        assert stats.class_stats, "trace replay must report class stats"
    finally:
        os.unlink(path)
