"""Serving example: batched requests through the paged engine under memory
pressure — preemptions and version-validated restarts happen live — then the
same workload with the refcounted prefix cache: every request carries the
same 8-token system prompt, so later admissions share its KV pages
(refcount += 1) and skip its prefill entirely.

Run: PYTHONPATH=src python examples/serve_paged.py
"""

from repro.launch.serve import main

BASE = ["--requests", "12", "--num-pages", "12", "--page-size", "8",
        "--max-batch", "4", "--prompt-len", "10", "--max-new", "20"]

if __name__ == "__main__":
    print("== no sharing: every prompt distinct, pool under pressure ==")
    main(BASE)
    print("== prefix sharing: common system prompt served from the cache ==")
    stats = main(BASE + ["--prefix-cache", "--shared-prefix", "8",
                         "--num-pages", "24"])
    assert stats.prefix_hits > 0, "shared prompts must hit the prefix index"
